"""Raster normalization (numpy path used by the pipeline jobs; the Pallas
kernel in repro.kernels.percentile_norm is the TPU runtime path and is
validated against :func:`percentile_stretch`)."""
from __future__ import annotations

import numpy as np


def percentile_stretch(img: np.ndarray, p_lo: float = 1.0,
                       p_hi: float = 99.0) -> np.ndarray:
    """Per-band [p_lo, p_hi] percentile clamp-and-stretch to [0,1]
    (paper Sect. II-B1)."""
    flat = img.reshape(-1, img.shape[-1]).astype(np.float32)
    lo = np.percentile(flat, p_lo, axis=0)
    hi = np.percentile(flat, p_hi, axis=0)
    out = (flat - lo) / np.maximum(hi - lo, 1e-12)
    return np.clip(out, 0.0, 1.0).reshape(img.shape).astype(np.float32)


def ndvi(img: np.ndarray, red: int = 0, nir: int = 3) -> np.ndarray:
    """Normalized Difference Vegetation Index (paper Sect. II-C2)."""
    r = img[..., red].astype(np.float32)
    n = img[..., nir].astype(np.float32)
    return (n - r) / np.maximum(n + r, 1e-6)


def evi(img: np.ndarray, red: int = 0, blue: int = 2, nir: int = 3
        ) -> np.ndarray:
    """Enhanced Vegetation Index (paper Sect. II-C2)."""
    r = img[..., red].astype(np.float32) / 1e4
    b = img[..., blue].astype(np.float32) / 1e4
    n = img[..., nir].astype(np.float32) / 1e4
    return 2.5 * (n - r) / np.maximum(n + 6 * r - 7.5 * b + 1.0, 1e-6)


def nir_rg(img: np.ndarray, red: int = 0, green: int = 1, nir: int = 3
           ) -> np.ndarray:
    """Color-shifted infrared composite NIR-R-G (paper Sect. II-C2)."""
    return percentile_stretch(np.stack(
        [img[..., nir], img[..., red], img[..., green]], axis=-1))
