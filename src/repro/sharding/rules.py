"""Parameter sharding rules.

Two layouts:

* ``dp``      — the paper-faithful layout: every parameter replicated, only
                the batch is sharded (the paper's per-job DDP on <=4 GPUs,
                scaled to the pod).
* ``fsdp_tp`` — the optimized layout implementing the paper's stated
                future work (multi-pod large-model training): parameters
                sharded over the ``data`` axis (FSDP/ZeRO-3 style) *and*
                tensor/expert-parallel over the ``model`` axis.  The
                ``pod`` axis (when present) is pure data parallelism over
                DCN — params replicated across pods.

Rules are path-pattern based so they apply uniformly to the stacked
(scan-over-layers) parameter trees of every architecture family.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# (regex over "/"-joined path, spec for the *last* ndims axes)
# Axis entries: "fsdp" -> data axis, "tp" -> model axis, None -> replicated.
_FSDP_TP_RULES = [
    (r"embed/w$",        ("tp", "fsdp")),
    (r"head/w$",         ("fsdp", "tp")),
    (r"attn/w[qkv]/w$",  ("fsdp", "tp")),
    (r"attn/wo/w$",      ("tp", "fsdp")),
    (r"(mlp|shared_mlp)/(up|gate)/w$", ("fsdp", "tp")),
    (r"(mlp|shared_mlp)/down/w$",      ("tp", "fsdp")),
    (r"moe/router/w$",   ("fsdp", None)),
    (r"moe/(up|gate)$",  ("tp", "fsdp", None)),
    (r"moe/down$",       ("tp", None, "fsdp")),
    (r"ssm/in_(z|x|B|C|dt)/w$", ("fsdp", "tp")),
    (r"ssm/out/w$",      ("tp", "fsdp")),
    (r"ssm/conv_w$",     (None, "tp")),
    (r"ssm/conv_b$",     ("tp",)),
    (r"ssm/norm_scale$", ("tp",)),
    (r"ssm/(dt_bias|A_log|D)$", (None,)),
    (r"(norm1|norm2|final_norm)/(scale|bias)$", (None,)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _axis_size(mesh, name) -> int:
    return dict(mesh.shape)[name]


def _resolve(mesh, shape, spec_tail, stacked: bool, axis_map) -> P:
    """Build a PartitionSpec, dropping axes that don't divide."""
    ndim = len(shape)
    tail = list(spec_tail)
    # leading dims not covered by the rule (e.g. the stacked period dim)
    entries = [None] * (ndim - len(tail)) + tail
    out = []
    for dim, ent in zip(shape, entries):
        name = axis_map.get(ent) if ent else None
        if name is not None:
            names = (name,) if isinstance(name, str) else name
            size = 1
            for n in names:
                size *= _axis_size(mesh, n)
            if dim % size != 0:
                name = None
        out.append(name)
    return P(*out)


# fsdp_sp overrides: with sequence-parallel activations, attention + SSM
# projection weights drop their tensor (model) axis — contraction-dim
# sharding would force a per-layer all-gather/all-reduce of full
# activations.  They FSDP over both mesh axes instead (same bytes/chip as
# (fsdp x tp)); the model axis is carried by the experts / vocab, whose
# exchanges (all-to-all, chunked loss) are cheap.
_FSDP_SP_OVERRIDES = [
    (r"attn/w[qkv]/w$",  ("fsdp2", None)),
    (r"attn/wo/w$",      ("fsdp2", None)),
    (r"ssm/in_(z|x|B|C|dt)/w$", ("fsdp2", None)),
    (r"ssm/out/w$",      ("fsdp2", None)),
    (r"ssm/conv_w$",     (None, "fsdp2")),
    (r"ssm/conv_b$",     ("fsdp2",)),
    (r"ssm/norm_scale$", ("fsdp2",)),
    # dense MLP / shared-expert weights keep the base (fsdp, tp) rule —
    # their Megatron-style AG/RS per layer is the textbook SP trade.
]


def param_shardings(param_tree, mesh, layout: str = "fsdp_tp"):
    """Pytree of NamedSharding matching ``param_tree`` (specs or arrays)."""
    have = set(mesh.axis_names)
    if layout == "dp":
        axis_map = {}
    elif layout in ("fsdp_tp", "fsdp_sp"):
        axis_map = {"fsdp": "data" if "data" in have else None,
                    "tp": "model" if "model" in have else None}
        # fsdp2: shard one weight dim over BOTH mesh axes (pure ZeRO-3)
        if "data" in have and "model" in have:
            axis_map["fsdp2"] = ("data", "model")
        elif "data" in have:
            axis_map["fsdp2"] = "data"
        axis_map = {k: v for k, v in axis_map.items() if v}
    else:
        raise ValueError(layout)

    rules = _FSDP_TP_RULES
    if layout == "fsdp_sp":
        rules = _FSDP_SP_OVERRIDES + _FSDP_TP_RULES

    def assign(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if layout != "dp":
            for pat, tail in rules:
                if re.search(pat, ps):
                    return NamedSharding(
                        mesh, _resolve(mesh, shape, tail, "periods" in ps,
                                       axis_map))
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return jax.tree_util.tree_map_with_path(assign, param_tree)


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def logical_axes(mesh, layout: str = "fsdp_tp") -> dict:
    """Logical activation axis -> mesh axis mapping for ShardCtx.

    * ``fsdp_tp`` — tensor-parallel activations: layer-boundary activations
      shard d_model ("embed") over ``model``; heads/mlp/experts/vocab also
      over ``model``.  XLA inserts an all-gather(d) before each projection
      and all-reduces partial outputs — measured at ~431 GB/chip/step for
      granite train_4k (see EXPERIMENTS.md §Perf).
    * ``fsdp_sp`` — sequence-parallel boundaries (beyond-paper layout):
      boundary activations shard the SEQUENCE over ``model`` instead, so
      norms, MLPs and routers are fully local; only attention (K/V gather)
      and MoE dispatch cross the ``model`` axis.
    """
    have = set(mesh.axis_names)
    batch = tuple(a for a in ("pod", "data") if a in have) or None
    if layout == "dp":
        return {"batch": batch}
    model = "model" if "model" in have else None
    if layout == "fsdp_sp":
        return {
            "batch": batch,
            "embed": None,
            "heads": None,
            "kv_heads": None,
            "mlp": None,
            "experts": model,
            "vocab": model,
            "seq": model,
        }
    return {
        "batch": batch,
        "embed": model,
        "heads": model,
        "kv_heads": model,
        "mlp": model,
        "experts": model,
        "vocab": model,
        "seq": None,       # boundaries are d-sharded in this layout
    }


def decode_state_shardings(state_tree, mesh, layout: str = "fsdp_tp"):
    """Shardings for the stacked decode caches.

    KV caches (periods, B, L, Kh, hd) shard batch over (pod, data) and the
    cache *sequence* dim over ``model`` (distributed KV — decode attention
    becomes a distributed softmax).  SSM states shard heads over ``model``.
    """
    have = set(mesh.axis_names)
    batch = tuple(a for a in ("pod", "data") if a in have) or None
    model = "model" if ("model" in have and layout != "dp") else None

    def assign(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        spec = [None] * len(shape)

        def put(dim, axis):
            if axis is None:
                return
            names = (axis,) if isinstance(axis, str) else axis
            size = 1
            for n in names:
                size *= _axis_size(mesh, n)
            if shape[dim] % size == 0:
                spec[dim] = axis

        if ps.endswith("/k") or ps.endswith("/v"):
            put(1, batch)   # (periods, B, L, Kh, hd)
            put(2, model)
        elif ps.endswith("/h"):
            put(1, batch)   # (periods, B, nh, hd, N)
            put(2, model)
        elif ps.endswith("/conv"):
            put(1, batch)   # (periods, B, W-1, C)
            put(3, model)
        else:
            put(1, batch)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(assign, state_tree)


def batch_sharding(mesh, ndim: int, batch_dim: int = 0,
                   batch_size: Optional[int] = None) -> NamedSharding:
    """Sharding for a data-batch array: batch dim over (pod, data)."""
    axes = batch_axes(mesh)
    if batch_size is not None:
        total = 1
        for a in axes:
            total *= _axis_size(mesh, a)
        if total and batch_size % total != 0:
            # fall back to whatever prefix divides (e.g. batch=1 -> replicate)
            keep = []
            prod = 1
            for a in axes:
                if batch_size % (prod * _axis_size(mesh, a)) == 0:
                    keep.append(a)
                    prod *= _axis_size(mesh, a)
            axes = tuple(keep)
    spec = [None] * ndim
    if axes:
        spec[batch_dim] = axes if len(axes) > 1 else axes[0]
    return NamedSharding(mesh, P(*spec))
