"""Activation-sharding context.

Model code calls :func:`constrain` on intermediate activations with
*logical* axis names ("batch", "seq", "embed", "heads", "experts",
"vocab").  The launcher installs a :class:`ShardCtx` mapping logical names
to mesh axes before tracing; on a bare CPU (smoke tests) no context is set
and every constraint is a no-op.  This keeps model code mesh-agnostic —
the same definition lowers for the single-pod, multi-pod, and
paper-faithful (pure-DP) layouts.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

_state = threading.local()


class ShardCtx:
    """Maps logical activation axes -> mesh axis names (or None)."""

    def __init__(self, mesh, logical: Dict[str, AxisVal]):
        self.mesh = mesh
        self.logical = dict(logical)

    def resolve(self, *axes: Optional[str]) -> P:
        return P(*[self.logical.get(a) if a else None for a in axes])


def set_ctx(ctx: Optional[ShardCtx]):
    _state.ctx = ctx


def current_ctx() -> Optional[ShardCtx]:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_ctx(ctx: Optional[ShardCtx]):
    prev = current_ctx()
    set_ctx(ctx)
    try:
        yield
    finally:
        set_ctx(prev)


def _dim_ok(shape_dim: int, mesh, axis: AxisVal) -> bool:
    if axis is None:
        return True
    names = (axis,) if isinstance(axis, str) else axis
    size = 1
    for n in names:
        size *= dict(mesh.shape)[n]
    return shape_dim % size == 0


def _resolve_logical(ctx, a) -> AxisVal:
    """A dim's logical spec may be one name or a tuple of names; tuples
    concatenate the resolved mesh axes (e.g. ("batch", "seq") -> the
    (pod, data, model) product sharding of a fused group dim)."""
    if a is None:
        return None
    if isinstance(a, tuple):
        out = []
        for part in a:
            v = ctx.logical.get(part)
            if v is None:
                continue
            out.extend((v,) if isinstance(v, str) else v)
        return tuple(out) if out else None
    return ctx.logical.get(a)


def constrain(x, *axes):
    """with_sharding_constraint against logical axes; no-op without ctx.

    Axes whose mesh extent does not divide the corresponding array dim are
    dropped (GSPMD would pad, but explicit specs must divide).
    """
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"constrain: rank {x.ndim} vs {len(axes)} axes")
    mesh = ctx.mesh
    spec_axes = []
    for dim, a in zip(x.shape, axes):
        v = _resolve_logical(ctx, a)
        if v is not None and not _dim_ok(dim, mesh, v):
            v = None
        spec_axes.append(v)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*spec_axes)))
