from repro.sharding.ctx import (
    ShardCtx,
    constrain,
    current_ctx,
    set_ctx,
)
from repro.sharding import rules

__all__ = ["ShardCtx", "constrain", "current_ctx", "set_ctx", "rules"]
