"""Batched serving engine: slot-based continuous batching over the
model's prefill/decode steps, with a fully device-resident hot path.

Requests are admitted into fixed decode slots (static shapes — TPU
friendly); each engine step decodes one token for every active slot.
Finished slots (EOS or max_tokens) are refilled from the queue.

Device-resident decode loop:
  * sampling (greedy + temperature/top-k via the JAX PRNG) is fused into
    the jitted decode step, so only (slots,) token ids and done-flags —
    never the (slots, vocab) logits — cross to host each token;
  * the decode state (KV caches / SSM states) plus the per-slot
    ``last_token``/``positions`` arrays are donated to the step
    (``donate_argnums``), so they are updated in place instead of copied;
  * admission inserts prefilled rows with one jitted, donated slot-insert
    (a masked gather) instead of a per-leaf host-side ``at[:, slot].set``;
  * prefill pads prompts to power-of-two buckets (capped at ``cache_len``)
    and runs one batched prefill per bucket, so the prefill jit cache is
    bounded by the number of buckets instead of growing per distinct
    prompt length.

The only per-token host work is bookkeeping of finished requests.
``submit`` validates prompts: empty prompts and prompts that cannot fit
the cache (``len(prompt) >= cache_len``) raise ``ValueError`` instead of
silently truncating.

Per-request service timing (submit/admit/first-token/done timestamps,
derived TTFT / TPOT / queue-wait) is recorded against the engine's
clock; ``engine.stats`` doubles as the raw counter dict (mapping access)
and, when *called*, returns a summary with latency percentiles — the
shape campaign ``RunReport`` aggregation expects.

:class:`repro.serve.scheduler.ServeScheduler` builds continuous-batching
admission (arrival process, SLO shedding, paged-KV eviction, streaming)
on top of the ``_select_admissions`` / ``_fill_slots`` / ``_retire``
hooks this class exposes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import (decode_and_sample, init_decode_state,
                          prefill_and_sample)

# Request lifecycle states
QUEUED = "queued"        # submitted, waiting for a slot
RUNNING = "running"      # occupying a decode slot
DONE = "done"            # retired normally (EOS / max_tokens / cache bound)
SHED = "shed"            # dropped by SLO admission before getting a slot


class Clock:
    """Wall clock; swappable for a :class:`VirtualClock` in tests/benches."""

    def now(self) -> float:
        return time.perf_counter()

    def sleep_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)

    def on_step(self) -> None:     # virtual clocks advance per decode step
        pass


class VirtualClock(Clock):
    """Deterministic clock: time moves only when told to.  ``dt_per_step``
    makes every decode step cost a fixed amount of virtual time, so
    queue-wait / deadline behaviour is reproducible in tests."""

    def __init__(self, start: float = 0.0, dt_per_step: float = 0.0):
        self.t = float(start)
        self.dt_per_step = float(dt_per_step)

    def now(self) -> float:
        return self.t

    def sleep_until(self, t: float) -> None:
        self.t = max(self.t, float(t))

    def advance(self, dt: float) -> None:
        self.t += float(dt)

    def on_step(self) -> None:
        self.t += self.dt_per_step


@dataclasses.dataclass(eq=False)   # identity equality: prompts are arrays
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32
    max_tokens: int = 16
    eos_id: Optional[int] = None
    # per-request sampling knobs: temperature <= 0 decodes greedily
    # (subject to the engine-level ``greedy`` default); top_k == 0 samples
    # the full vocab.
    temperature: float = 0.0
    top_k: int = 0
    # scheduling knobs (JobSpec.priority semantics: higher runs first;
    # deadline_ms is a TTFT SLO measured from submit time — the scheduler
    # sheds requests that can no longer meet it)
    priority: int = 0
    deadline_ms: Optional[float] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = QUEUED
    evictions: int = 0
    # streaming: called as on_token(request, token_id, finished) from the
    # host bookkeeping loop the moment each token id reaches the host
    on_token: Optional[Callable[["Request", int, bool], None]] = \
        dataclasses.field(default=None, repr=False, compare=False)
    # service timestamps (engine-clock seconds; filled by the engine)
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None

    # ------------------------------------------------- derived latencies
    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (submit -> first token on host)."""
        if self.t_first is None or self.t_submit is None:
            return None
        return self.t_first - self.t_submit

    @property
    def tpot_s(self) -> Optional[float]:
        """Time per output token over the decode phase."""
        if self.t_done is None or self.t_first is None:
            return None
        return ((self.t_done - self.t_first)
                / max(1, len(self.generated) - 1))

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.t_admit is None or self.t_submit is None:
            return None
        return self.t_admit - self.t_submit

    def met_deadline(self) -> bool:
        """Did the first token arrive within the TTFT SLO?"""
        if self.status != DONE:
            return False
        if self.deadline_ms is None:
            return True
        ttft = self.ttft_s
        return ttft is not None and ttft * 1e3 <= self.deadline_ms


def validate_request(req: Request, cache_len: int) -> None:
    """Reject prompts the engine cannot serve faithfully: empty prompts
    have no token to prefill from; prompts >= cache_len would silently
    lose their head to the ring buffer."""
    plen = len(req.prompt)
    if plen == 0:
        raise ValueError(f"request {req.rid}: empty prompt — a request "
                         f"needs at least one prompt token")
    if plen >= cache_len:
        raise ValueError(
            f"request {req.rid}: prompt length {plen} >= cache_len "
            f"{cache_len}; the cache holds at most cache_len - 1 prompt "
            f"tokens plus one generated token — shorten the prompt or "
            f"serve with a larger cache_len")


class EngineStats(dict):
    """The engine's raw counters (plain mapping access, e.g.
    ``stats["decode_steps"]``) that is also *callable*: ``stats()``
    returns a summary dict with per-request latency percentiles."""

    def __init__(self, engine: "ServeEngine", **counters):
        super().__init__(**counters)
        self._engine = engine

    def __call__(self) -> Dict[str, object]:
        return self._engine._stats_summary()


def _pctl(values: List[float], q: float) -> Optional[float]:
    vals = [v for v in values if v is not None]
    if not vals:
        return None
    return round(float(np.percentile(np.asarray(vals, np.float64), q)), 6)


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 cache_len: int = 256, greedy: bool = True, seed: int = 0,
                 min_bucket: int = 8, clock: Optional[Clock] = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.greedy = greedy
        self.min_bucket = min_bucket
        self.clock = clock or Clock()

        self.state = init_decode_state(cfg, slots, cache_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.completed: List[Request] = []

        # device-resident per-slot decode inputs (never pulled per token)
        self.last_token = jnp.zeros((slots,), jnp.int32)
        self.positions = jnp.zeros((slots,), jnp.int32)
        self._temps = jnp.zeros((slots,), jnp.float32)
        self._topks = jnp.zeros((slots,), jnp.int32)
        self._eos = jnp.full((slots,), -1, jnp.int32)
        # host bookkeeping mirror of positions (advanced analytically — no
        # device readback)
        self._host_pos = np.zeros(slots, np.int64)

        self._base_key = jax.random.PRNGKey(seed)
        self._tick = 0
        self._decode_traces = 0
        self.stats = EngineStats(
            self, decode_steps=0, host_transfer_bytes=0, prefill_calls=0,
            admitted=0)

        def fused_decode(p, state, last_tok, pos, base_key, tick,
                         temps, topks, eos, sampling):
            # Python body runs only while jax traces (i.e. compiles) a new
            # program — this counter is therefore the decode compile count
            self._decode_traces += 1
            key = jax.random.fold_in(base_key, tick)
            tok, new_state = decode_and_sample(
                p, cfg, state, last_tok[:, None], pos, key, temps, topks,
                greedy_only=not sampling)
            return new_state, tok, pos + 1, tok == eos

        # `sampling` is static: the all-greedy decode program (the common
        # case) skips the full-vocab sort + categorical draw; at most two
        # programs are ever traced
        self._decode = jax.jit(fused_decode, donate_argnums=(1, 2, 3),
                               static_argnums=(9,))
        self._needs_sampling = False

        def slot_insert(state, pstate, last_tok, pos, src_row, ptoks, plens):
            """Scatter prefilled rows into engine slots: slot s takes
            prefill row src_row[s] (or keeps its state if src_row[s] < 0)."""
            take = src_row >= 0
            row = jnp.maximum(src_row, 0)

            def put(e, n):
                g = jnp.take(n, row, axis=1)
                m = take.reshape((1, -1) + (1,) * (e.ndim - 2))
                return jnp.where(m, g.astype(e.dtype), e)

            new_state = jax.tree.map(put, state, pstate)
            last = jnp.where(take, jnp.take(ptoks, row), last_tok)
            newpos = jnp.where(take, jnp.take(plens, row), pos)
            return new_state, last, newpos

        self._insert = jax.jit(slot_insert, donate_argnums=(0, 1, 2, 3))
        self._prefill_cache: Dict[int, object] = {}

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        validate_request(req, self.cache_len)
        if req.t_submit is None:
            req.t_submit = self.clock.now()
        req.status = QUEUED
        self.queue.append(req)

    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill programs traced so far (≤ bucket count)."""
        return len(self._prefill_cache)

    @property
    def decode_compiles(self) -> int:
        """Distinct decode programs traced so far (≤ 2: greedy-only and
        sampling variants).  Flat after warmup — continuous admission
        must never retrace the decode step."""
        return self._decode_traces

    def bucket(self, plen: int) -> int:
        """Power-of-two pad target for a prompt length, ≥ min_bucket and
        capped at cache_len (the longest admissible prompt)."""
        b = max(self.min_bucket, 1 << max(0, plen - 1).bit_length())
        return min(b, self.cache_len)

    def n_buckets(self) -> int:
        """Upper bound on distinct prefill programs this engine can trace."""
        return len({self.bucket(p) for p in range(1, self.cache_len)})

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_cache:
            cfg, cache_len = self.cfg, self.cache_len

            @jax.jit
            def fn(params, toks, lengths, base_key, tick, temps, topks):
                key = jax.random.fold_in(base_key, tick)
                return prefill_and_sample(
                    params, cfg, {"tokens": toks}, cache_len=cache_len,
                    key=key, temperature=temps, top_k=topks, lengths=lengths)
            self._prefill_cache[bucket] = fn
        return self._prefill_cache[bucket]

    def _effective_sampling(self, req: Request):
        temp = float(req.temperature)
        if temp <= 0.0 and not self.greedy:
            temp = 1.0
        return temp, int(req.top_k)

    # --------------------------------------------------- admission hooks
    def _prompt_tokens(self, req: Request) -> np.ndarray:
        """Tokens to prefill for an admitted request.  The scheduler
        overrides this to re-prefill prompt+generated on eviction resume."""
        return np.asarray(req.prompt)

    def _select_admissions(self) -> List:
        """Admission policy: (slot, request) pairs to admit this tick.
        Base engine: FIFO into free slots.  The scheduler overrides this
        with priority order, SLO shedding and paged-KV budgeting."""
        free = [s for s in range(self.slots) if self.active[s] is None]
        pairs = []
        while free and self.queue:
            pairs.append((free.pop(0), self.queue.pop(0)))
        return pairs

    def _admit(self):
        admitted = self._select_admissions()
        if not admitted:
            return
        self._fill_slots(admitted)
        self._sync_slot_meta()

    def _fill_slots(self, admitted: List):
        """Prefill + insert the selected (slot, request) pairs, grouped by
        pad bucket so the prefill jit cache stays bounded."""
        groups: Dict[int, list] = {}
        for slot, req in admitted:
            toks_np = self._prompt_tokens(req)
            plen = min(len(toks_np), self.cache_len - 1)
            groups.setdefault(self.bucket(plen), []).append(
                (slot, req, toks_np, plen))

        for bucket, grp in sorted(groups.items()):
            # fixed (slots, bucket) prefill batch — rows beyond the group
            # are dummies (length 0, state discarded by the insert mask)
            toks = np.zeros((self.slots, bucket), np.int32)
            lens = np.zeros(self.slots, np.int32)
            temps = np.zeros(self.slots, np.float32)
            topks = np.zeros(self.slots, np.int32)
            src_row = np.full(self.slots, -1, np.int32)
            for r, (slot, req, toks_np, plen) in enumerate(grp):
                toks[r, :plen] = toks_np[-plen:]
                lens[r] = plen
                temps[r], topks[r] = self._effective_sampling(req)
                src_row[slot] = r
            self._tick += 1
            ptoks, pstate = self._prefill_fn(bucket)(
                self.params, jnp.asarray(toks), jnp.asarray(lens),
                self._base_key, np.int32(self._tick), jnp.asarray(temps),
                jnp.asarray(topks))
            self.state, self.last_token, self.positions = self._insert(
                self.state, pstate, self.last_token, self.positions,
                jnp.asarray(src_row), ptoks, jnp.asarray(lens))
            first = np.asarray(ptoks)          # (slots,) — admit-time only
            self.stats["prefill_calls"] += 1
            now = self.clock.now()
            for r, (slot, req, toks_np, plen) in enumerate(grp):
                self.active[slot] = req
                req.status = RUNNING
                if req.t_admit is None:
                    req.t_admit = now
                tok = int(first[r])
                req.generated.append(tok)
                if req.t_first is None:
                    req.t_first = now
                self._host_pos[slot] = plen
                self.stats["admitted"] += 1
                finished = len(req.generated) >= req.max_tokens
                if finished:
                    self._retire(slot, req)
                if req.on_token:
                    req.on_token(req, tok, finished)

    def _sync_slot_meta(self):
        """Refresh the per-slot sampling/EOS device arrays (admit-time
        host→device upload; nothing here runs per token)."""
        temps = np.zeros(self.slots, np.float32)
        topks = np.zeros(self.slots, np.int32)
        eos = np.full(self.slots, -1, np.int32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            temps[slot], topks[slot] = self._effective_sampling(req)
            if req.eos_id is not None:
                eos[slot] = req.eos_id
        self._temps = jnp.asarray(temps)
        self._topks = jnp.asarray(topks)
        self._eos = jnp.asarray(eos)
        self._needs_sampling = bool((temps > 0.0).any())

    # ------------------------------------------------------- retirement
    def _retire(self, slot: int, req: Request):
        """Free a slot whose request finished normally."""
        req.done = True
        req.status = DONE
        req.t_done = self.clock.now()
        self.completed.append(req)
        self.active[slot] = None

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One decode step across all active slots.  Returns whether a
        decode actually ran (False: nothing active after admission)."""
        self._admit()
        return self._decode_tick()

    def _decode_tick(self) -> bool:
        """Decode one token for every active slot (no admission)."""
        if not any(r is not None for r in self.active):
            return False
        self._tick += 1
        self.state, tok, self.positions, eos_hit = \
            self._decode(self.params, self.state, self.last_token,
                         self.positions, self._base_key,
                         np.int32(self._tick), self._temps, self._topks,
                         self._eos, self._needs_sampling)
        self.last_token = tok
        # the ONLY per-token device→host transfer: token ids + done flags
        tok_h = np.asarray(tok)
        eos_h = np.asarray(eos_hit)
        self.stats["decode_steps"] += 1
        self.stats["host_transfer_bytes"] += tok_h.nbytes + eos_h.nbytes
        self._host_pos += 1
        self.clock.on_step()

        retired = False
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok_i = int(tok_h[slot])
            req.generated.append(tok_i)
            finished = (bool(eos_h[slot])
                        or len(req.generated) >= req.max_tokens
                        or self._host_pos[slot] >= self.cache_len - 1)
            if finished:
                self._retire(slot, req)
                retired = True
            if req.on_token:
                req.on_token(req, tok_i, finished)
        if retired:
            self._sync_slot_meta()
        return True

    def run(self, max_steps: int = 1000) -> List[Request]:
        for _ in range(max_steps):
            self.step()
            if not self.queue and all(r is None for r in self.active):
                break
        return self.completed

    # ------------------------------------------------------------ stats
    def _stats_extra(self) -> Dict[str, object]:
        """Engine-specific stats()-summary fields (scheduler overrides)."""
        return {}

    def _stats_summary(self) -> Dict[str, object]:
        done = [r for r in self.completed if r.status == DONE]
        ttft = [r.ttft_s for r in done]
        tpot = [r.tpot_s for r in done]
        qwait = [r.queue_wait_s for r in done]
        summary = {
            "completed": len(done),
            "queued": len(self.queue),
            "running": sum(r is not None for r in self.active),
            "decode_steps": self.stats["decode_steps"],
            "prefill_calls": self.stats["prefill_calls"],
            "admitted": self.stats["admitted"],
            "host_transfer_bytes": self.stats["host_transfer_bytes"],
            "prefill_compiles": self.prefill_compiles,
            "decode_compiles": self.decode_compiles,
            "evictions": sum(r.evictions for r in done),
            "ttft_p50_s": _pctl(ttft, 50), "ttft_p99_s": _pctl(ttft, 99),
            "tpot_p50_s": _pctl(tpot, 50), "tpot_p99_s": _pctl(tpot, 99),
            "queue_wait_p50_s": _pctl(qwait, 50),
            "queue_wait_p99_s": _pctl(qwait, 99),
        }
        summary.update(self._stats_extra())
        return summary
