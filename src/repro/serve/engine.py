"""Batched serving engine: slot-based continuous batching over the
model's prefill/decode steps, with a fully device-resident hot path.

Requests are admitted into fixed decode slots (static shapes — TPU
friendly); each engine step decodes one token for every active slot.
Finished slots (EOS or max_tokens) are refilled from the queue.

Device-resident decode loop:
  * sampling (greedy + temperature/top-k via the JAX PRNG) is fused into
    the jitted decode step, so only (slots,) token ids and done-flags —
    never the (slots, vocab) logits — cross to host each token;
  * the decode state (KV caches / SSM states) plus the per-slot
    ``last_token``/``positions`` arrays are donated to the step
    (``donate_argnums``), so they are updated in place instead of copied;
  * admission inserts prefilled rows with one jitted, donated slot-insert
    (a masked gather) instead of a per-leaf host-side ``at[:, slot].set``;
  * prefill pads prompts to power-of-two buckets (capped at ``cache_len``)
    and runs one batched prefill per bucket, so the prefill jit cache is
    bounded by the number of buckets instead of growing per distinct
    prompt length.

The only per-token host work is bookkeeping of finished requests.
Prompts longer than ``cache_len - 1`` are truncated to their last
``cache_len - 1`` tokens at admission.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import (decode_and_sample, init_decode_state,
                          prefill_and_sample)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (P,) int32
    max_tokens: int = 16
    eos_id: Optional[int] = None
    # per-request sampling knobs: temperature <= 0 decodes greedily
    # (subject to the engine-level ``greedy`` default); top_k == 0 samples
    # the full vocab.
    temperature: float = 0.0
    top_k: int = 0
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 cache_len: int = 256, greedy: bool = True, seed: int = 0,
                 min_bucket: int = 8):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.greedy = greedy
        self.min_bucket = min_bucket

        self.state = init_decode_state(cfg, slots, cache_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.completed: List[Request] = []

        # device-resident per-slot decode inputs (never pulled per token)
        self.last_token = jnp.zeros((slots,), jnp.int32)
        self.positions = jnp.zeros((slots,), jnp.int32)
        self._temps = jnp.zeros((slots,), jnp.float32)
        self._topks = jnp.zeros((slots,), jnp.int32)
        self._eos = jnp.full((slots,), -1, jnp.int32)
        # host bookkeeping mirror of positions (advanced analytically — no
        # device readback)
        self._host_pos = np.zeros(slots, np.int64)

        self._base_key = jax.random.PRNGKey(seed)
        self._tick = 0
        self.stats = {"decode_steps": 0, "host_transfer_bytes": 0,
                      "prefill_calls": 0, "admitted": 0}

        def fused_decode(p, state, last_tok, pos, base_key, tick,
                         temps, topks, eos, sampling):
            key = jax.random.fold_in(base_key, tick)
            tok, new_state = decode_and_sample(
                p, cfg, state, last_tok[:, None], pos, key, temps, topks,
                greedy_only=not sampling)
            return new_state, tok, pos + 1, tok == eos

        # `sampling` is static: the all-greedy decode program (the common
        # case) skips the full-vocab sort + categorical draw; at most two
        # programs are ever traced
        self._decode = jax.jit(fused_decode, donate_argnums=(1, 2, 3),
                               static_argnums=(9,))
        self._needs_sampling = False

        def slot_insert(state, pstate, last_tok, pos, src_row, ptoks, plens):
            """Scatter prefilled rows into engine slots: slot s takes
            prefill row src_row[s] (or keeps its state if src_row[s] < 0)."""
            take = src_row >= 0
            row = jnp.maximum(src_row, 0)

            def put(e, n):
                g = jnp.take(n, row, axis=1)
                m = take.reshape((1, -1) + (1,) * (e.ndim - 2))
                return jnp.where(m, g.astype(e.dtype), e)

            new_state = jax.tree.map(put, state, pstate)
            last = jnp.where(take, jnp.take(ptoks, row), last_tok)
            newpos = jnp.where(take, jnp.take(plens, row), pos)
            return new_state, last, newpos

        self._insert = jax.jit(slot_insert, donate_argnums=(0, 1, 2, 3))
        self._prefill_cache: Dict[int, object] = {}

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill programs traced so far (≤ bucket count)."""
        return len(self._prefill_cache)

    def bucket(self, plen: int) -> int:
        """Power-of-two pad target for a prompt length, ≥ min_bucket and
        capped at cache_len (the longest admissible prompt)."""
        b = max(self.min_bucket, 1 << max(0, plen - 1).bit_length())
        return min(b, self.cache_len)

    def n_buckets(self) -> int:
        """Upper bound on distinct prefill programs this engine can trace."""
        return len({self.bucket(p) for p in range(1, self.cache_len)})

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill_cache:
            cfg, cache_len = self.cfg, self.cache_len

            @jax.jit
            def fn(params, toks, lengths, base_key, tick, temps, topks):
                key = jax.random.fold_in(base_key, tick)
                return prefill_and_sample(
                    params, cfg, {"tokens": toks}, cache_len=cache_len,
                    key=key, temperature=temps, top_k=topks, lengths=lengths)
            self._prefill_cache[bucket] = fn
        return self._prefill_cache[bucket]

    def _effective_sampling(self, req: Request):
        temp = float(req.temperature)
        if temp <= 0.0 and not self.greedy:
            temp = 1.0
        return temp, int(req.top_k)

    def _admit(self):
        free = [s for s in range(self.slots) if self.active[s] is None]
        if not free or not self.queue:
            return
        admitted = []
        while free and self.queue:
            admitted.append((free.pop(0), self.queue.pop(0)))

        groups: Dict[int, list] = {}
        for slot, req in admitted:
            plen = min(len(req.prompt), self.cache_len - 1)
            groups.setdefault(self.bucket(plen), []).append((slot, req, plen))

        for bucket, grp in sorted(groups.items()):
            # fixed (slots, bucket) prefill batch — rows beyond the group
            # are dummies (length 0, state discarded by the insert mask)
            toks = np.zeros((self.slots, bucket), np.int32)
            lens = np.zeros(self.slots, np.int32)
            temps = np.zeros(self.slots, np.float32)
            topks = np.zeros(self.slots, np.int32)
            src_row = np.full(self.slots, -1, np.int32)
            for r, (slot, req, plen) in enumerate(grp):
                toks[r, :plen] = np.asarray(req.prompt)[-plen:]
                lens[r] = plen
                temps[r], topks[r] = self._effective_sampling(req)
                src_row[slot] = r
            self._tick += 1
            ptoks, pstate = self._prefill_fn(bucket)(
                self.params, jnp.asarray(toks), jnp.asarray(lens),
                self._base_key, np.int32(self._tick), jnp.asarray(temps),
                jnp.asarray(topks))
            self.state, self.last_token, self.positions = self._insert(
                self.state, pstate, self.last_token, self.positions,
                jnp.asarray(src_row), ptoks, jnp.asarray(lens))
            first = np.asarray(ptoks)          # (slots,) — admit-time only
            self.stats["prefill_calls"] += 1
            for r, (slot, req, plen) in enumerate(grp):
                self.active[slot] = req
                req.generated.append(int(first[r]))
                self._host_pos[slot] = plen
                self.stats["admitted"] += 1
        self._sync_slot_meta()

    def _sync_slot_meta(self):
        """Refresh the per-slot sampling/EOS device arrays (admit-time
        host→device upload; nothing here runs per token)."""
        temps = np.zeros(self.slots, np.float32)
        topks = np.zeros(self.slots, np.int32)
        eos = np.full(self.slots, -1, np.int32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            temps[slot], topks[slot] = self._effective_sampling(req)
            if req.eos_id is not None:
                eos[slot] = req.eos_id
        self._temps = jnp.asarray(temps)
        self._topks = jnp.asarray(topks)
        self._eos = jnp.asarray(eos)
        self._needs_sampling = bool((temps > 0.0).any())

    # ------------------------------------------------------------------
    def step(self):
        """One decode step across all active slots."""
        self._admit()
        if not any(r is not None for r in self.active):
            return
        self._tick += 1
        self.state, tok, self.positions, eos_hit = \
            self._decode(self.params, self.state, self.last_token,
                         self.positions, self._base_key,
                         np.int32(self._tick), self._temps, self._topks,
                         self._eos, self._needs_sampling)
        self.last_token = tok
        # the ONLY per-token device→host transfer: token ids + done flags
        tok_h = np.asarray(tok)
        eos_h = np.asarray(eos_hit)
        self.stats["decode_steps"] += 1
        self.stats["host_transfer_bytes"] += tok_h.nbytes + eos_h.nbytes
        self._host_pos += 1

        retired = False
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.generated.append(int(tok_h[slot]))
            if (bool(eos_h[slot])
                    or len(req.generated) >= req.max_tokens
                    or self._host_pos[slot] >= self.cache_len - 1):
                req.done = True
                self.completed.append(req)
                self.active[slot] = None
                retired = True
        if retired:
            self._sync_slot_meta()

    def run(self, max_steps: int = 1000) -> List[Request]:
        for _ in range(max_steps):
            self.step()
            if not self.queue and all(r is None for r in self.active):
                break
        return self.completed
