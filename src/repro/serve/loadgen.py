"""Open-loop load generation for the serve scheduler.

Open-loop means arrivals follow the trace's clock regardless of how the
server is keeping up — the regime that actually stresses admission,
shedding and eviction (a closed-loop driver self-throttles and can never
overload the engine).  Two arrival processes:

* :func:`poisson_trace` — exponential inter-arrival gaps at a target
  mean rate (the classic steady-traffic model);
* :func:`bursty_trace` — arrivals grouped into near-simultaneous bursts
  separated by idle gaps (same mean rate, much worse tail behaviour —
  flash-crowd traffic).

Both return a sorted ``[(arrival_time_s, Request), ...]`` list with
deterministic prompts/lengths per seed, ready for
``ServeScheduler.submit_trace`` or for replaying against the static
:class:`~repro.serve.ServeEngine` baseline.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.engine import Request

Trace = List[Tuple[float, Request]]


def _requests(vocab: int, n: int, rng: np.random.Generator, *,
              plen_range: Tuple[int, int], max_tokens: int,
              priorities: Sequence[int], deadline_ms: Optional[float],
              rid_base: int) -> List[Request]:
    lo, hi = plen_range
    return [
        Request(rid=rid_base + i,
                prompt=rng.integers(0, vocab,
                                    size=int(rng.integers(lo, hi + 1))),
                max_tokens=max_tokens,
                priority=int(priorities[int(rng.integers(
                    0, len(priorities)))]),
                deadline_ms=deadline_ms)
        for i in range(n)
    ]


def poisson_trace(vocab: int, n: int, rate_qps: float, *, seed: int = 0,
                  plen_range: Tuple[int, int] = (4, 24),
                  max_tokens: int = 16,
                  priorities: Sequence[int] = (0,),
                  deadline_ms: Optional[float] = None,
                  rid_base: int = 0, start: float = 0.0) -> Trace:
    """n arrivals with Exp(1/rate) inter-arrival gaps (mean rate_qps)."""
    if rate_qps <= 0:
        raise ValueError(f"rate_qps must be positive, got {rate_qps}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_qps, size=n)
    times = start + np.cumsum(gaps)
    reqs = _requests(vocab, n, rng, plen_range=plen_range,
                     max_tokens=max_tokens, priorities=priorities,
                     deadline_ms=deadline_ms, rid_base=rid_base)
    return list(zip(times.tolist(), reqs))


def bursty_trace(vocab: int, n: int, rate_qps: float, *, seed: int = 0,
                 burst_size: int = 4, jitter_s: float = 1e-3,
                 plen_range: Tuple[int, int] = (4, 24),
                 max_tokens: int = 16,
                 priorities: Sequence[int] = (0,),
                 deadline_ms: Optional[float] = None,
                 rid_base: int = 0, start: float = 0.0) -> Trace:
    """Same mean rate as :func:`poisson_trace`, but arrivals land in
    bursts of ``burst_size`` (small intra-burst jitter) separated by
    Exp(burst_size/rate) gaps — flash-crowd tails."""
    if rate_qps <= 0:
        raise ValueError(f"rate_qps must be positive, got {rate_qps}")
    if burst_size <= 0:
        raise ValueError(f"burst_size must be positive, got {burst_size}")
    rng = np.random.default_rng(seed)
    n_bursts = -(-n // burst_size)
    burst_gaps = rng.exponential(burst_size / rate_qps, size=n_bursts)
    burst_t = start + np.cumsum(burst_gaps)
    times = []
    for b in range(n_bursts):
        k = min(burst_size, n - len(times))
        times.extend((burst_t[b] + rng.uniform(0, jitter_s, size=k))
                     .tolist())
    times.sort()
    reqs = _requests(vocab, n, rng, plen_range=plen_range,
                     max_tokens=max_tokens, priorities=priorities,
                     deadline_ms=deadline_ms, rid_base=rid_base)
    return list(zip(times, reqs))


def make_trace(kind: str, vocab: int, n: int, rate_qps: float,
               **kw) -> Trace:
    """Dispatch by name ('poisson' | 'bursty') — the CLI/bench surface."""
    if kind == "poisson":
        return poisson_trace(vocab, n, rate_qps, **kw)
    if kind == "bursty":
        return bursty_trace(vocab, n, rate_qps, **kw)
    raise ValueError(f"unknown trace kind {kind!r} "
                     f"(expected 'poisson' or 'bursty')")
