"""The pre-device-resident serving engine, kept verbatim as the perf
baseline for ``benchmarks/serve_bench.py`` and the equivalence oracle for
the refactored engine's tests.

Known costs (all eliminated by :class:`repro.serve.ServeEngine`):
  * every decode step ships the full (slots, vocab) logits array to host
    and samples there;
  * the decode state is functionally copied each step (no donation);
  * each admit runs an unjitted full-pytree ``at[:, slot].set`` copy;
  * prefill compiles once per *distinct prompt length* (unbounded jit
    cache) and runs one request at a time.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import decode_step, init_decode_state, prefill
from repro.serve.engine import Request, validate_request


class LegacyServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 cache_len: int = 256, greedy: bool = True, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.greedy = greedy
        self.rng = np.random.default_rng(seed)

        self.state = init_decode_state(cfg, slots, cache_len)
        self.positions = np.zeros(slots, np.int64)   # next position to write
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.last_token = np.zeros(slots, np.int64)

        self._decode = jax.jit(
            lambda p, s, t, pos: decode_step(p, cfg, s, t, pos))
        self._prefill_cache: Dict[int, object] = {}

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        # same input contract as the new engines (the oracle must see the
        # same trace the engine under test accepted)
        validate_request(req, self.cache_len)
        self.queue.append(req)

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill_cache:
            cfg, cache_len = self.cfg, self.cache_len

            @jax.jit
            def fn(params, toks):
                return prefill(params, cfg, {"tokens": toks},
                               cache_len=cache_len)
            self._prefill_cache[plen] = fn
        return self._prefill_cache[plen]

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            plen = len(req.prompt)
            logits, st = self._prefill_fn(plen)(
                self.params, jnp.asarray(req.prompt, jnp.int32)[None, :])
            # copy this request's row-0 state into the engine slot
            def put(engine_leaf, new_leaf):
                return engine_leaf.at[:, slot].set(new_leaf[:, 0])
            self.state = jax.tree.map(put, self.state, st)
            tok = self._pick(np.asarray(logits)[0])
            self.active[slot] = req
            req.generated.append(int(tok))
            self.positions[slot] = plen
            self.last_token[slot] = tok

    def _pick(self, logits: np.ndarray) -> int:
        if self.greedy:
            return int(np.argmax(logits))
        p = np.exp(logits - logits.max())
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    # ------------------------------------------------------------------
    def step(self):
        """One decode step across all active slots."""
        self._admit()
        if not any(r is not None for r in self.active):
            return
        toks = jnp.asarray(self.last_token, jnp.int32)[:, None]
        pos = jnp.asarray(self.positions, jnp.int32)
        logits, self.state = self._decode(self.params, self.state, toks, pos)
        logits = np.asarray(logits)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = self._pick(logits[slot])
            req.generated.append(tok)
            self.positions[slot] += 1
            self.last_token[slot] = tok
            if ((req.eos_id is not None and tok == req.eos_id)
                    or len(req.generated) >= req.max_tokens
                    or self.positions[slot] >= self.cache_len - 1):
                req.done = True
                self.completed.append(req)
                self.active[slot] = None

    def run(self, max_steps: int = 1000) -> List[Request]:
        for _ in range(max_steps):
            self.step()
            if not self.queue and all(r is None for r in self.active):
                break
        return self.completed
