"""Continuous-batching serve scheduler: live-traffic admission in front
of the device-resident decode loop.

:class:`ServeScheduler` extends :class:`repro.serve.ServeEngine` with the
pieces a static slot model lacks:

* **Arrival process** — requests carry an arrival time
  (:meth:`submit_at`); pending arrivals are released into the ready
  queue as the engine clock passes them, and new requests enter freed
  slots *mid-decode* on the very tick the slot frees — through the same
  donated ``slot_insert``/bucketed-prefill path PR 2 compiled, so
  admission never retraces the decode program (``decode_compiles`` stays
  flat across any trace).
* **SLO-aware admission** — the ready queue is ordered by
  ``Request.priority`` (``JobSpec.priority`` semantics: higher first,
  FIFO within a class).  A request whose TTFT deadline
  (``deadline_ms``, defaulted from ``slo_deadline_ms``) has already
  expired while queued is *shed* instead of wasting a slot on an answer
  nobody is waiting for.
* **Paged KV budgeting** — logical cache capacity comes from a
  :class:`repro.serve.kv_alloc.PagedKVAllocator` pool that may be
  smaller than ``slots * cache_len``.  Admission reserves blocks for the
  prompt; each decode tick grows the table by the new token.  When the
  pool is exhausted the LRU victim is evicted: its blocks are recycled,
  and the request is re-queued to resume later by re-prefilling
  ``prompt + generated`` (greedy decode resumes token-for-token
  identically — vLLM-style recompute preemption).
* **Token streaming** — per-request ``on_token`` callbacks fire from the
  host loop, and :meth:`stream` yields tokens as the host sees them
  (TTFT is stamped when the first token is appended, i.e. at first
  yield).

The physical decode state is untouched: fixed-shape slot tensors, the
donated decode step, and bucketed prefill are all inherited, so the
greedy scheduler is token-for-token identical to ``LegacyServeEngine``
on a fixed-arrival trace (CI-enforced).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.serve.engine import (DONE, QUEUED, SHED, Clock, Request,
                                ServeEngine, validate_request)
from repro.serve.kv_alloc import PagedKVAllocator


class ServeScheduler(ServeEngine):
    def __init__(self, cfg: ArchConfig, params, *, slots: int = 4,
                 cache_len: int = 256, greedy: bool = True, seed: int = 0,
                 min_bucket: int = 8, clock: Optional[Clock] = None,
                 max_kv_blocks: Optional[int] = None,
                 kv_block_size: int = 16,
                 slo_deadline_ms: Optional[float] = None):
        super().__init__(cfg, params, slots=slots, cache_len=cache_len,
                         greedy=greedy, seed=seed, min_bucket=min_bucket,
                         clock=clock)
        if max_kv_blocks is None:
            # default pool covers every slot at full depth (no eviction
            # pressure unless the caller opts into oversubscription)
            max_kv_blocks = slots * (-(-cache_len // kv_block_size))
        self.kv = PagedKVAllocator(max_kv_blocks, kv_block_size)
        if self.kv.total_blocks * self.kv.block_size < cache_len:
            raise ValueError(
                f"max_kv_blocks={max_kv_blocks} x block_size="
                f"{kv_block_size} cannot hold even one full-depth request "
                f"(cache_len={cache_len}) — a lone request could deadlock")
        self.slo_deadline_ms = slo_deadline_ms
        self.shed: List[Request] = []
        # (arrival_time, seq, request) — released into `queue` by time
        self._pending: List[Tuple[float, int, Request]] = []
        self._seq = itertools.count()
        self._order: Dict[int, int] = {}       # rid -> submit order
        self.stats["shed"] = 0
        self.stats["evictions"] = 0

    # --------------------------------------------------------- arrivals
    def submit(self, req: Request):
        if req.deadline_ms is None:
            req.deadline_ms = self.slo_deadline_ms
        self._order.setdefault(req.rid, next(self._seq))
        super().submit(req)

    def submit_at(self, req: Request, arrival_time: float):
        """Schedule an open-loop arrival: the request joins the ready
        queue once the engine clock reaches ``arrival_time``."""
        validate_request(req, self.cache_len)   # fail at submit, not later
        if req.t_submit is None:
            req.t_submit = float(arrival_time)   # TTFT counts from arrival
        heapq.heappush(self._pending, (float(arrival_time),
                                       next(self._seq), req))

    def submit_trace(self, trace: Iterable[Tuple[float, Request]]):
        for t, req in trace:
            self.submit_at(req, t)

    def _release_arrivals(self):
        now = self.clock.now()
        while self._pending and self._pending[0][0] <= now:
            _, _, req = heapq.heappop(self._pending)
            self.submit(req)

    def next_arrival(self) -> Optional[float]:
        return self._pending[0][0] if self._pending else None

    # -------------------------------------------------------- admission
    def _shed_expired(self):
        """Drop queued requests whose TTFT deadline already passed — a
        slot spent on them is goodput denied to a request that can still
        make its SLO."""
        now = self.clock.now()
        keep = []
        for req in self.queue:
            # requests with a first token already out (eviction resumes)
            # have met or missed their TTFT SLO — shedding them now would
            # throw away delivered work, so they always re-run
            if (req.t_first is None
                    and req.deadline_ms is not None
                    and req.t_submit is not None
                    and (now - req.t_submit) * 1e3 > req.deadline_ms):
                req.status = SHED
                req.t_done = now
                self.shed.append(req)
                self.stats["shed"] += 1
                if req.on_token:
                    req.on_token(req, -1, True)
            else:
                keep.append(req)
        self.queue = keep

    def _select_admissions(self) -> List:
        self._release_arrivals()
        self._shed_expired()
        free = [s for s in range(self.slots) if self.active[s] is None]
        if not free or not self.queue:
            return []
        # priority queue: higher priority first, FIFO within a class
        # (JobSpec.priority semantics, same ordering the campaign
        # executor applies)
        self.queue.sort(key=lambda r: (-r.priority, self._order[r.rid]))
        pairs, deferred = [], []
        for req in self.queue:
            if not free:
                deferred.append(req)
                continue
            need = len(self._prompt_tokens(req)) + 1
            if not self.kv.admit(req.rid, need, priority=req.priority,
                                 tick=self._tick):
                # pool exhausted: head-of-line waits for blocks to recycle
                deferred.append(req)
                continue
            pairs.append((free.pop(0), req))
        self.queue = deferred
        return pairs

    def _prompt_tokens(self, req: Request) -> np.ndarray:
        """Eviction resume: the whole history (prompt + tokens generated
        before eviction) is re-prefilled as the new prompt; greedy decode
        then continues exactly where it left off."""
        prompt = np.asarray(req.prompt)
        if req.generated:
            return np.concatenate(
                [prompt, np.asarray(req.generated, prompt.dtype)])
        return prompt

    # ------------------------------------------------------- retirement
    def _retire(self, slot: int, req: Request):
        if self.kv.table(req.rid) is not None:
            self.kv.release(req.rid)
        super()._retire(slot, req)

    def _evict(self, slot: int, req: Request):
        """Recycle a running request's blocks and re-queue it: it resumes
        later by re-prefilling prompt + generated."""
        self.kv.release(req.rid)
        self.active[slot] = None
        req.status = QUEUED
        req.evictions += 1
        self.stats["evictions"] += 1
        history = len(req.prompt) + len(req.generated)
        if history >= self.cache_len - 1:
            # no room left to resume — it was about to hit the cache
            # bound anyway; retire it as done instead of looping forever
            req.done = True
            req.status = DONE
            req.t_done = self.clock.now()
            self.completed.append(req)
        else:
            self.queue.append(req)

    def _ensure_decode_capacity(self):
        """Before a decode tick, every active request needs its next
        token's cache row covered by the block pool; evict LRU victims
        until every survivor fits."""
        evicted = False
        for slot in range(self.slots):
            req = self.active[slot]
            if req is None:
                continue
            need = int(self._host_pos[slot]) + 1
            while not self.kv.grow(req.rid, need, tick=self._tick):
                victim_rid = self.kv.lru_victim(exclude={req.rid})
                if victim_rid is None:       # nobody else to evict
                    self._evict(slot, req)
                    evicted = True
                    break
                vslot = next(s for s, r in enumerate(self.active)
                             if r is not None and r.rid == victim_rid)
                self._evict(vslot, self.active[vslot])
                evicted = True
        if evicted:
            self._sync_slot_meta()

    # ------------------------------------------------------------ drive
    def step(self) -> bool:
        self._admit()
        self._ensure_decode_capacity()
        return self._decode_tick()

    def idle(self) -> bool:
        return (not self._pending and not self.queue
                and all(r is None for r in self.active))

    def run(self, max_steps: int = 100_000) -> List[Request]:
        """Drive the engine until every submitted request is done or
        shed.  Open-loop: between now and a future arrival with nothing
        active, the clock sleeps forward instead of busy-spinning."""
        for _ in range(max_steps):
            progressed = self.step()
            if self.idle():
                break
            if not progressed and not self.queue:
                nxt = self.next_arrival()
                if nxt is not None:
                    self.clock.sleep_until(nxt)
        return self.completed

    run_trace = run

    # -------------------------------------------------------- streaming
    def stream(self, req: Request, max_steps: int = 100_000) \
            -> Iterator[int]:
        """Yield ``req``'s tokens as the host sees them, driving the
        engine (and every co-batched request) underneath.  TTFT is
        measured at the first yield; a shed request yields nothing."""
        if (req.status == QUEUED and req not in self.queue
                and all(req is not p[2] for p in self._pending)):
            self.submit(req)
        emitted = 0
        for _ in range(max_steps):
            while emitted < len(req.generated):
                yield req.generated[emitted]
                emitted += 1
            if req.done or req.status == SHED:
                return
            if not self.step() and not self.queue:
                nxt = self.next_arrival()
                if nxt is None:
                    return           # nothing left anywhere
                self.clock.sleep_until(nxt)

    # ------------------------------------------------------------ stats
    def _stats_extra(self) -> Dict[str, object]:
        done = [r for r in self.completed if r.status == DONE]
        return {
            "shed": self.stats["shed"],
            "evictions": self.stats["evictions"],
            "slo_met": sum(r.met_deadline() for r in done),
            "kv": self.kv.snapshot(),
        }
