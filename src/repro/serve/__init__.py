from repro.serve.engine import ServeEngine, Request
from repro.serve.legacy import LegacyServeEngine

__all__ = ["ServeEngine", "Request", "LegacyServeEngine"]
