from repro.serve.engine import (Clock, Request, ServeEngine, VirtualClock,
                                validate_request)
from repro.serve.kv_alloc import PagedKVAllocator
from repro.serve.legacy import LegacyServeEngine
from repro.serve.loadgen import bursty_trace, make_trace, poisson_trace
from repro.serve.scheduler import ServeScheduler

__all__ = [
    "ServeEngine", "Request", "LegacyServeEngine", "ServeScheduler",
    "PagedKVAllocator", "Clock", "VirtualClock", "validate_request",
    "poisson_trace", "bursty_trace", "make_trace",
]
