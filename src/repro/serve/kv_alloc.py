"""Paged KV-cache allocator: block tables over a shared token-block pool.

The physical decode caches stay fixed-shape per slot (``cache_len`` rows
— the TPU-friendly layout PR 2's donated decode step requires), but
*logical* cache capacity is accounted here in fixed-size token blocks
drawn from one shared pool.  That decouples ``cache_len`` (the
per-request ceiling) from the aggregate KV budget: a scheduler can run
``slots`` concurrent requests against a pool smaller than
``slots * cache_len`` because typical requests never grow to the
ceiling.  Each request owns a block table (list of block ids); blocks
are appended as the sequence grows, recycled on completion, and
reclaimed by evicting a victim request when the pool is exhausted.

Eviction policy (``lru_victim``): least-recently-*scheduled* request
first (stale entries lose their blocks before hot ones); among equally
recent requests the lowest ``priority`` loses first, and ties break
toward the most recently admitted — evicting the newest request
preserves the most accumulated decode work, mirroring vLLM's recompute
preemption.  The allocator only does accounting and victim selection;
requeue/re-prefill of the evicted request is the scheduler's job.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set


@dataclasses.dataclass
class BlockTable:
    """Per-request view of the pool: which blocks hold its tokens."""
    rid: int
    blocks: List[int]
    n_tokens: int = 0            # logical sequence length accounted for
    priority: int = 0            # JobSpec.priority semantics: higher first
    last_used: int = 0           # scheduler tick of the last grow/touch
    admit_seq: int = 0           # monotone admission counter


class PagedKVAllocator:
    """Fixed pool of ``total_blocks`` blocks of ``block_size`` tokens."""

    def __init__(self, total_blocks: int, block_size: int = 16):
        if total_blocks <= 0 or block_size <= 0:
            raise ValueError("total_blocks and block_size must be positive, "
                             f"got {total_blocks} x {block_size}")
        self.total_blocks = total_blocks
        self.block_size = block_size
        self._free: List[int] = list(range(total_blocks - 1, -1, -1))
        self._tables: Dict[int, BlockTable] = {}
        self._admit_seq = 0
        self.stats = {"allocated_blocks": 0, "freed_blocks": 0,
                      "peak_blocks_in_use": 0, "failed_grows": 0}

    # ------------------------------------------------------------ sizing
    def blocks_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.block_size))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.total_blocks - len(self._free)

    def table(self, rid: int) -> Optional[BlockTable]:
        return self._tables.get(rid)

    def holders(self) -> List[int]:
        return list(self._tables)

    def can_admit(self, n_tokens: int) -> bool:
        return self.blocks_for(n_tokens) <= len(self._free)

    # -------------------------------------------------------- lifecycle
    def admit(self, rid: int, n_tokens: int, *, priority: int = 0,
              tick: int = 0) -> bool:
        """Reserve blocks for a request entering a slot with ``n_tokens``
        already in (or about to enter) its cache.  False if the pool
        cannot cover it (caller evicts and retries, or keeps it queued)."""
        if rid in self._tables:
            raise ValueError(f"rid {rid} already holds a block table")
        need = self.blocks_for(n_tokens)
        if need > len(self._free):
            self.stats["failed_grows"] += 1
            return False
        blocks = [self._free.pop() for _ in range(need)]
        self._admit_seq += 1
        self._tables[rid] = BlockTable(
            rid=rid, blocks=blocks, n_tokens=n_tokens, priority=priority,
            last_used=tick, admit_seq=self._admit_seq)
        self.stats["allocated_blocks"] += need
        self._note_peak()
        return True

    def grow(self, rid: int, n_tokens: int, *, tick: int = 0) -> bool:
        """Extend ``rid`` to cover ``n_tokens`` total; allocates new
        blocks as the sequence crosses block boundaries.  False (with no
        partial allocation) if the pool is exhausted."""
        t = self._tables[rid]
        t.last_used = tick
        need = self.blocks_for(n_tokens) - len(t.blocks)
        if need <= 0:
            t.n_tokens = max(t.n_tokens, n_tokens)
            return True
        if need > len(self._free):
            self.stats["failed_grows"] += 1
            return False
        t.blocks.extend(self._free.pop() for _ in range(need))
        t.n_tokens = n_tokens
        self.stats["allocated_blocks"] += need
        self._note_peak()
        return True

    def release(self, rid: int) -> int:
        """Recycle every block ``rid`` holds (completion or eviction).
        Returns the number of blocks returned to the pool."""
        t = self._tables.pop(rid)
        self._free.extend(reversed(t.blocks))
        self.stats["freed_blocks"] += len(t.blocks)
        return len(t.blocks)

    # --------------------------------------------------------- eviction
    def lru_victim(self, exclude: Set[int] = frozenset()) -> Optional[int]:
        """The request to evict when the pool is exhausted: least
        recently used, then lowest priority, then newest admission."""
        candidates = [t for rid, t in self._tables.items()
                      if rid not in exclude]
        if not candidates:
            return None
        victim = min(candidates,
                     key=lambda t: (t.last_used, t.priority, -t.admit_seq))
        return victim.rid

    def _note_peak(self):
        self.stats["peak_blocks_in_use"] = max(
            self.stats["peak_blocks_in_use"], self.used_blocks)

    def snapshot(self) -> Dict[str, object]:
        """Accounting view for stats()/bench reports."""
        return {
            "total_blocks": self.total_blocks,
            "block_size": self.block_size,
            "used_blocks": self.used_blocks,
            "free_blocks": self.free_blocks,
            "peak_blocks_in_use": self.stats["peak_blocks_in_use"],
            "failed_grows": self.stats["failed_grows"],
        }
